"""Serving example: batched autoregressive decode with a KV cache on an
assigned architecture (smoke scale), incl. a grown model — demonstrating
that a progressively-trained checkpoint serves identically to a fixed one.

    PYTHONPATH=src python examples/serve_decode.py [--arch gemma2-9b]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import configs as cfglib
from repro.core import expansion as exp
from repro.models import registry
from repro.train.serve_lib import Generator

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-9b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

cfg = cfglib.get_smoke_config(args.arch)
api = registry.get_model(cfg)

# a "progressively grown" model: 1 super-block source expanded to full depth
period = cfg.pattern_period
src = api.init(jax.random.PRNGKey(0), cfg, num_layers=period)
params = exp.expand_params(src, cfg.with_depth(period), cfg.num_layers,
                           "copying_stack")
print(f"serving {cfg.name}: {cfg.num_layers} layers "
      f"(grown from {period}), vocab {cfg.vocab_size}")

rng = np.random.default_rng(0)
prompts = rng.integers(0, cfg.vocab_size, (args.batch, 8)).astype(np.int32)
gen = Generator(cfg, params, max_len=8 + args.gen + 1)
t0 = time.perf_counter()
out = gen.generate(prompts, args.gen, temperature=0.8, seed=1)
dt = time.perf_counter() - t0
print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
      f"(prefill {out.prefill_tokens} tok in one forward: "
      f"{args.batch * out.prefill_tokens / max(out.prefill_s, 1e-9):.0f} tok/s; "
      f"decode {args.batch * max(out.steps - 1, 0) / max(out.decode_s, 1e-9):.0f} tok/s)")
print("sample:", out.tokens[0].tolist())
