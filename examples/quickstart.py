"""Quickstart: the paper's recipe in ~40 lines.

Trains a ZERO-layer GPT2-style model for 60% of the horizon, expands to the
4-layer target with random initialization during the WSD stable phase, and
shows (i) the loss spike at expansion, (ii) mixing back toward the
fixed-size run, (iii) the compute savings of eq (1.1).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import (ExpansionConfig, ModelConfig, OptimizerConfig,
                                ScheduleConfig, TrainConfig)
from repro.core.mixing import compute_savings
from repro.train import loop

model = ModelConfig(name="quickstart", family="dense", num_layers=4,
                    d_model=128, num_heads=4, num_kv_heads=4, d_ff=512,
                    vocab_size=512, attention="mha", activation="gelu",
                    norm="layernorm", position="absolute", tie_embeddings=True,
                    max_seq_len=128)

STEPS, TAU = 150, 0.6
train_cfg = TrainConfig(
    total_steps=STEPS, seq_len=64, global_batch=8,
    source_layers=0,                                   # zero-layer source!
    expansions=(ExpansionConfig(at_frac=TAU, target_layers=4, init="random"),),
    optimizer=OptimizerConfig(name="muon_nsgd", learning_rate=0.02),
    schedule=ScheduleConfig(name="wsd", decay_frac=0.2),
    eval_every=10**9, log_every=5, checkpoint_every=10**9)

print("=== zero-layer progressive training (paper recipe, §7) ===")
result = loop.train(model, train_cfg)

h = result.history
print(f"\nexpansion at step {h['expansion_steps']}; "
      f"final loss {h['loss'][-1]:.4f} at depth {result.final_layers}")

sav = compute_savings(STEPS, int(TAU * STEPS),
                      model.with_depth(0).param_count(),
                      model.param_count(), 64 * 8)
print(f"compute: {sav['savings']:.1%} saved vs fixed-size "
      f"({sav['speedup']:.2f}x speedup) — eq (1.1)")
