"""Continuous-batching serving example: staggered request admission.

Eight requests with ragged prompt/generation lengths arrive over ~100ms
(Poisson).  The scheduler prefills each one alone at its exact prompt
length, scatters it into the first freed cache slot, and every iteration
advances ALL live rows one token at their own cursors — no row ever waits
for another request to finish.  Compare the streamed completion order and
per-request TTFT against what a batch-to-completion engine would do (stall
everything on the longest request of the batch).

    PYTHONPATH=src python examples/serve_continuous.py [--arch gpt2-12l]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro import configs as cfglib
from repro.models import registry
from repro.train.serve_engine import ServeEngine
from repro.train.serve_scheduler import (ContinuousScheduler, Request,
                                         summarize)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gpt2-12l")
ap.add_argument("--max-batch", type=int, default=4)
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--temperature", type=float, default=0.0)
args = ap.parse_args()

if args.arch in cfglib.ASSIGNED_ARCHS:
    cfg = cfglib.get_smoke_config(args.arch)
else:                       # CPU-scale reduction (as in the smoke tests)
    import dataclasses
    cfg = dataclasses.replace(
        cfglib.get_config(args.arch).with_depth(2), d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        max_seq_len=64)
api = registry.get_model(cfg)
params = api.init(jax.random.PRNGKey(0), cfg)

rng = np.random.default_rng(0)
p_lens = rng.integers(4, 17, args.requests)
g_lens = rng.integers(4, 25, args.requests)
arrivals = np.cumsum(rng.exponential(0.015, args.requests))
reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                    (int(p),)).astype(np.int32),
                max_new_tokens=int(g), arrival_s=float(a))
        for p, g, a in zip(p_lens, g_lens, arrivals)]

engine = ServeEngine(cfg, params,
                     max_len=int(p_lens.max() + g_lens.max() + 1))
sched = ContinuousScheduler(engine, max_batch=args.max_batch,
                            temperature=args.temperature)
print(f"serving {cfg.name} ({cfg.num_layers} layers), "
      f"{args.requests} requests into {args.max_batch} slots")
sched.warmup(reqs)   # compile the per-length prefills outside the timed run
t0 = time.perf_counter()
results = sched.run(reqs, on_finish=lambda r: print(
    f"  [{time.perf_counter() - t0:6.3f}s] req {r.uid} done: "
    f"P={len(r.prompt)} +{len(r.new_tokens)} tok slot={r.slot} "
    f"ttft={r.ttft_s * 1e3:.1f}ms"))
stats = summarize(results, time.perf_counter() - t0)
print(f"aggregate: {stats['generated_tokens']} tokens in "
      f"{stats['wall_s']:.3f}s = {stats['tokens_per_s']:.1f} tok/s; "
      f"ttft p50 {stats['ttft_p50_s'] * 1e3:.1f}ms / "
      f"p95 {stats['ttft_p95_s'] * 1e3:.1f}ms")
print("sample:", results[0].tokens.tolist())
