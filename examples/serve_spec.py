"""Self-speculative decoding example: progressive training's free draft.

A shallow model is depth-expanded with ``copying_zeroL`` (the paper's
function-preserving recipe), then served speculatively: the expanded
model's own depth prefix at the pre-expansion depth is the draft — no
separate draft training, no extra parameter memory (block leaves are
views of the target's stacked scan axis).  Because the expansion is
function-preserving, every greedy draft proposal matches and the
acceptance rate is exactly 1.0: each speculation round replaces γ+1
full-depth decode steps with γ+1 shallow draft steps plus ONE multi-token
verify forward through the paged KV cache's block tables.  Rejected
tokens (on a real training run, where the deep model has learned more
than its prefix) roll back by per-row cursor rewind + page release — no
page data ever moves, and the greedy streams stay byte-identical to
non-speculative decode.

    PYTHONPATH=src python examples/serve_spec.py [--gamma 4]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import expansion as exp
from repro.models import registry
from repro.train.serve_engine import ServeEngine
from repro.train.serve_scheduler import (ContinuousScheduler, Request,
                                         summarize)

ap = argparse.ArgumentParser()
ap.add_argument("--gamma", type=int, default=4)
ap.add_argument("--draft-layers", type=int, default=2)
ap.add_argument("--target-layers", type=int, default=12)
ap.add_argument("--max-batch", type=int, default=4)
ap.add_argument("--requests", type=int, default=12)
args = ap.parse_args()

base = ModelConfig(name="spec-demo", family="dense",
                   num_layers=args.draft_layers, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=256, max_seq_len=128)
deep = base.with_depth(args.target_layers)
shallow = registry.get_model(base).init(jax.random.PRNGKey(0), base)
params = exp.expand_params(shallow, base, args.target_layers,
                           "copying_zeroL")

rng = np.random.default_rng(0)
p_lens = rng.integers(4, 17, args.requests)
g_lens = rng.integers(6, 25, args.requests)
arrivals = np.cumsum(rng.exponential(0.01, args.requests))
reqs = [Request(prompt=rng.integers(0, base.vocab_size,
                                    (int(p),)).astype(np.int32),
                max_new_tokens=int(g), arrival_s=float(a))
        for p, g, a in zip(p_lens, g_lens, arrivals)]
max_len = int(p_lens.max() + g_lens.max() + 1)

print(f"serving {deep.num_layers}-layer copying_zeroL expansion; draft = "
      f"its first {args.draft_layers} layers (shared embed/head), "
      f"gamma={args.gamma}")
for spec in (False, True):
    eng = ServeEngine(deep, params, max_len=max_len, paged=True,
                      block_size=8, spec_decode=spec, gamma=args.gamma,
                      draft_depth=args.draft_layers if spec else None)
    sched = ContinuousScheduler(eng, max_batch=args.max_batch)
    sched.warmup(reqs)
    t0 = time.perf_counter()
    results = sched.run(reqs)
    stats = summarize(results, time.perf_counter() - t0)
    label = "speculative" if spec else "paged baseline"
    line = (f"{label:>15}: {stats['tokens_per_s']:7.1f} tokens/s  "
            f"ttft p50={stats['ttft_p50_s'] * 1e3:.1f}ms")
    if spec:
        line += (f"  acceptance={sched.acceptance_rate:.0%} "
                 f"(rounds={sched.spec_stats()['spec_rounds']})")
    print(line)
